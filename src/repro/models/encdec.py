"""Whisper-style encoder-decoder (audio). The conv frontend is a stub per
the assignment: ``input_specs()`` provides precomputed frame embeddings of
shape (B, encoder_context, d_model). LayerNorm + GELU MLP, sinusoidal
positions (no RoPE), bidirectional encoder self-attn, causal decoder
self-attn + cross-attn.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import common as cm
from repro.models import dense
from repro.models.common import ParamSpec, ShardCtx, shard


def _ln_specs(d, dtype):
    return {"scale": ParamSpec((d,), ("embed",), dtype, "ones"),
            "bias": ParamSpec((d,), ("embed",), dtype, "zeros")}


def _mlp_specs(arch, dtype):
    d, ff = arch.d_model, arch.d_ff
    return {
        "wi": ParamSpec((d, ff), ("embed", "mlp"), dtype),
        "bi": ParamSpec((ff,), ("mlp",), dtype, "zeros"),
        "wo": ParamSpec((ff, d), ("mlp", "embed"), dtype),
        "bo": ParamSpec((d,), ("embed",), dtype, "zeros"),
    }


def enc_layer_specs(arch: ArchConfig, dtype) -> Dict[str, Any]:
    return {
        "ln1": _ln_specs(arch.d_model, dtype),
        "ln2": _ln_specs(arch.d_model, dtype),
        "attn": dense.attn_param_specs(arch, dtype),
        "mlp": _mlp_specs(arch, dtype),
    }


def dec_layer_specs(arch: ArchConfig, dtype) -> Dict[str, Any]:
    return {
        "ln1": _ln_specs(arch.d_model, dtype),
        "ln_x": _ln_specs(arch.d_model, dtype),
        "ln2": _ln_specs(arch.d_model, dtype),
        "attn": dense.attn_param_specs(arch, dtype),
        "xattn": dense.attn_param_specs(arch, dtype),
        "mlp": _mlp_specs(arch, dtype),
    }


def param_specs(arch: ArchConfig) -> Dict[str, Any]:
    dtype = jnp.dtype(arch.parallel.param_dtype)
    return {
        "encoder": dense._stack_specs(enc_layer_specs(arch, dtype),
                                      arch.n_encoder_layers),
        "enc_ln_f": _ln_specs(arch.d_model, dtype),
        "decoder": dense._stack_specs(dec_layer_specs(arch, dtype),
                                      arch.n_layers),
    }


def _ln(x, p, eps):
    return cm.layer_norm(x, p["scale"].astype(jnp.float32),
                         p["bias"].astype(jnp.float32), eps)


def _mha(p, xq, xkv, arch: ArchConfig, ctx: ShardCtx, *, causal: bool):
    """Whisper attention: no RoPE (positions are additive sinusoids)."""
    a = arch.attn
    cd = xq.dtype
    B, S, _ = xq.shape
    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", xkv, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", xkv, p["wv"].astype(cd))
    G = a.num_heads // a.num_kv_heads
    qg = q.reshape(B, S, a.num_kv_heads, G, a.head_dim)
    out = cm.attention(qg, k, v, causal=causal, window=None,
                       chunk=min(arch.parallel.attn_chunk, S))
    out = out.reshape(B, S, a.num_heads, a.head_dim)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd)), k, v


def encode(params, frames, arch: ArchConfig, ctx: ShardCtx):
    """frames: (B, T_enc, d) stub embeddings -> encoder output."""
    B, T, d = frames.shape
    h = frames + cm.sinusoidal_positions(T, d).astype(frames.dtype)

    def body(x, lp):
        hh = _ln(x, lp["ln1"], arch.norm_eps)
        a, _, _ = _mha(lp["attn"], hh, hh, arch, ctx, causal=False)
        x = x + a
        hh = _ln(x, lp["ln2"], arch.norm_eps)
        x = x + cm.gelu_mlp(hh, lp["mlp"]["wi"], lp["mlp"]["bi"],
                            lp["mlp"]["wo"], lp["mlp"]["bo"])
        return x, None

    body = dense._remat(body, arch.parallel.remat_policy)
    h, _ = lax.scan(body, h, params["encoder"])
    return _ln(h, params["enc_ln_f"], arch.norm_eps)


def decode_forward(params, h, enc_out, arch: ArchConfig, ctx: ShardCtx,
                   collect_kv: bool = False):
    """Teacher-forcing decoder pass. h: (B, S, d) token embeddings."""
    B, S, d = h.shape
    h = h + cm.sinusoidal_positions(S, d).astype(h.dtype)

    def body(x, lp):
        a, k, v = _mha(lp["attn"], _ln(x, lp["ln1"], arch.norm_eps),
                       _ln(x, lp["ln1"], arch.norm_eps), arch, ctx,
                       causal=True)
        x = x + a
        xa, xk, xv = _mha(lp["xattn"], _ln(x, lp["ln_x"], arch.norm_eps),
                          enc_out, arch, ctx, causal=False)
        x = x + xa
        hh = _ln(x, lp["ln2"], arch.norm_eps)
        x = x + cm.gelu_mlp(hh, lp["mlp"]["wi"], lp["mlp"]["bi"],
                            lp["mlp"]["wo"], lp["mlp"]["bo"])
        if collect_kv:
            return x, ((k, v), (xk, xv))
        return x, None

    body = dense._remat(body, arch.parallel.remat_policy)
    h, kv = lax.scan(body, h, params["decoder"])
    return h, kv


def forward(params, h, arch: ArchConfig, ctx: ShardCtx, *, positions=None,
            encoder_frames=None, collect_kv: bool = False):
    enc_out = encode(params, encoder_frames, arch, ctx)
    h, kv = decode_forward(params, h, enc_out, arch, ctx, collect_kv)
    return h, {"kv": kv, "enc_out": enc_out}


def cache_specs(arch: ArchConfig, batch: int, seq: int,
                kv_quant: bool = False) -> Dict[str, Any]:
    a = arch.attn
    L = arch.n_layers
    T_enc = arch.encoder_context
    xkv = ParamSpec((L, batch, T_enc, a.num_kv_heads, a.head_dim),
                    ("layers", "batch", None, "kv_heads", None),
                    jnp.bfloat16, "zeros")
    if not kv_quant:
        kv = ParamSpec((L, batch, seq, a.num_kv_heads, a.head_dim),
                       ("layers", "batch", "cache_seq", "kv_heads", None),
                       jnp.bfloat16, "zeros")
        self_part = {"k": kv, "v": kv}
    else:
        mq, kq = arch.kv_quant.m_bytes, arch.kv_quant.codebook_size
        codes = ParamSpec((L, batch, seq, a.num_kv_heads, mq),
                          ("layers", "batch", "cache_seq", "kv_heads", None),
                          jnp.uint8, "zeros")
        cb = ParamSpec((L, a.num_kv_heads, mq, kq, a.head_dim),
                       ("layers", "kv_heads", None, None, None),
                       jnp.bfloat16, "normal")
        self_part = {"k_codes": codes, "v_codes": codes,
                     "k_cb": cb, "v_cb": cb}
    return {"self": self_part, "cross_k": xkv, "cross_v": xkv}


def decode_step(params, cache, h, pos, arch: ArchConfig, ctx: ShardCtx, *,
                kv_quant: bool = False):
    """One decoder token step; cross-attn reads the precomputed cross KV."""
    a = arch.attn
    B = h.shape[0]
    d = arch.d_model
    h = h + cm.sinusoidal_positions(1, d, offset=pos).astype(h.dtype)
    big = jnp.int32(1 << 30)

    def body(x, xs):
        lp, self_cache, xk, xv = xs
        # self-attention via the dense decode path (no rope: theta irrelevant
        # because whisper adds sinusoids to h; emulate by zero positions)
        x2, new_self = _self_decode(lp, self_cache, x, pos, arch, ctx,
                                    kv_quant)
        # cross-attention to the precomputed encoder KV
        xq = _ln(x2, lp["ln_x"], arch.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", xq, lp["xattn"]["wq"].astype(x.dtype))
        G = a.num_heads // a.num_kv_heads
        qg = q.reshape(B, a.num_kv_heads, G, a.head_dim)
        T = xk.shape[1]
        cl = min(512, T) if T % min(512, T) == 0 else T
        nch = T // cl

        def chunks(i):
            return (lax.dynamic_slice_in_dim(xk, i * cl, cl, 1),
                    lax.dynamic_slice_in_dim(xv, i * cl, cl, 1))

        out = cm.decode_attention(qg, chunks, nch, cl, T)
        out = out.reshape(B, 1, a.num_heads, a.head_dim)
        x2 = x2 + jnp.einsum("bshk,hkd->bsd", out,
                             lp["xattn"]["wo"].astype(x.dtype))
        hh = _ln(x2, lp["ln2"], arch.norm_eps)
        x2 = x2 + cm.gelu_mlp(hh, lp["mlp"]["wi"], lp["mlp"]["bi"],
                              lp["mlp"]["wo"], lp["mlp"]["bo"])
        return x2, new_self

    h, new_self = lax.scan(body, h, (params["decoder"], cache["self"],
                                     cache["cross_k"], cache["cross_v"]))
    return h, dict(cache, self=new_self)


def _self_decode(lp, self_cache, x, pos, arch, ctx, kv_quant):
    """Whisper decoder self-attn single step (LayerNorm, no RoPE)."""
    a = arch.attn
    B = x.shape[0]
    h = _ln(x, lp["ln1"], arch.norm_eps)
    cd = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wq"].astype(cd))
    k_new = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wk"].astype(cd))
    v_new = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wv"].astype(cd))
    G = a.num_heads // a.num_kv_heads
    qg = q.reshape(B, a.num_kv_heads, G, a.head_dim)
    if not kv_quant:
        k_cache = lax.dynamic_update_slice_in_dim(
            self_cache["k"], k_new.astype(self_cache["k"].dtype), pos, axis=1)
        v_cache = lax.dynamic_update_slice_in_dim(
            self_cache["v"], v_new.astype(self_cache["v"].dtype), pos, axis=1)
        new_self = {"k": k_cache, "v": v_cache}
        T = k_cache.shape[1]
        cl = min(2048, T)
        nch = T // cl

        def chunks(i):
            return (lax.dynamic_slice_in_dim(k_cache, i * cl, cl, 1),
                    lax.dynamic_slice_in_dim(v_cache, i * cl, cl, 1))
    else:
        kc = dense._rq_encode_vec(k_new[:, 0], self_cache["k_cb"])
        vc = dense._rq_encode_vec(v_new[:, 0], self_cache["v_cb"])
        k_codes = lax.dynamic_update_slice_in_dim(
            self_cache["k_codes"], kc[:, None], pos, axis=1)
        v_codes = lax.dynamic_update_slice_in_dim(
            self_cache["v_codes"], vc[:, None], pos, axis=1)
        new_self = dict(self_cache, k_codes=k_codes, v_codes=v_codes)
        T = k_codes.shape[1]
        cl = min(2048, T)
        nch = T // cl

        def chunks(i):
            return (dense._dequant_chunk(
                        lax.dynamic_slice_in_dim(k_codes, i * cl, cl, 1),
                        self_cache["k_cb"]),
                    dense._dequant_chunk(
                        lax.dynamic_slice_in_dim(v_codes, i * cl, cl, 1),
                        self_cache["v_cb"]))

    out = cm.decode_attention(qg, chunks, nch, cl, pos + 1)
    out = out.reshape(B, 1, a.num_heads, a.head_dim)
    x = x + jnp.einsum("bshk,hkd->bsd", out, lp["attn"]["wo"].astype(cd))
    return x, new_self
