from repro.configs.base import ArchConfig, AttnConfig, MoEConfig, SSMConfig, ShapeConfig, SHAPES, SHAPE_BY_NAME, shape_applicable
from repro.configs.registry import get_arch, list_archs
