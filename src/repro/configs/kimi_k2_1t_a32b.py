from repro.configs.registry import get_arch

CONFIG = get_arch("kimi_k2_1t_a32b")
