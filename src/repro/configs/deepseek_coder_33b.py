from repro.configs.registry import get_arch

CONFIG = get_arch("deepseek_coder_33b")
