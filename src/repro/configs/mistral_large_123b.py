from repro.configs.registry import get_arch

CONFIG = get_arch("mistral_large_123b")
