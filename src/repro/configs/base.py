"""Config dataclasses for architectures, shapes, and parallelism policies.

Every assigned architecture gets one module in this package exporting a
single ``CONFIG: ArchConfig``. The registry maps ``--arch`` ids to them.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int           # per-expert hidden dim
    capacity_factor: float = 1.25
    num_shared_experts: int = 0
    d_ff_shared: int = 0
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int              # N (per-head SSM state)
    head_dim: int = 64          # P (channels per SSD head)
    expand: int = 2             # d_inner = expand * d_model
    conv_width: int = 4
    chunk_size: int = 256       # SSD block size for the dual (quadratic) form
    ngroups: int = 1            # B/C groups (GVA-style)


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    # sliding-window pattern: window size for "local" layers; a layer is
    # global every `global_every` layers (gemma3: window=1024, global_every=6).
    window: Optional[int] = None
    global_every: int = 1       # 1 => every layer global (no local layers)
    logit_softcap: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class KVQuantConfig:
    """BEYOND-PAPER: residual-quantized KV cache (core/kv_quant.py)."""
    enabled: bool = False
    m_bytes: int = 4            # RQ codebooks per K/V head vector
    codebook_size: int = 256


@dataclasses.dataclass(frozen=True)
class ParallelPolicy:
    """How the arch maps onto the (pod, data, model) mesh."""
    fsdp: bool = False          # shard params/opt-state over `data` too
    expert_parallel: bool = False
    pipeline_stages: int = 1    # >1 => GPipe over the pod axis
    remat_policy: str = "dots"  # nothing | dots | full
    param_dtype: str = "float32"
    opt_state_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    grad_compress_pods: bool = False  # int8 cross-pod gradient exchange
    attn_chunk: int = 512       # query-block size for chunked flash attention
    # --- perf-iteration knobs (EXPERIMENTS.md §Perf) ---
    dp_only: bool = False       # no TP: model axis joins data (small archs)
    parallel_block: bool = False  # PaLM-style fused attn+MLP: 1 TP AR/layer
    moe_2d: bool = False        # experts over model x expert-FFN over data:
                                # expert weights never all-gathered (FSDP
                                # applies to the attention/dense 3% only)
    grad_compress_in_graph: bool = False  # shard_map int8 pod-axis exchange
                                # inside train_step (perf variant; the
                                # collective itself lives in core/grad_compress)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attn: Optional[AttnConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    kv_quant: KVQuantConfig = dataclasses.field(default_factory=KVQuantConfig)
    parallel: ParallelPolicy = dataclasses.field(default_factory=ParallelPolicy)
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # hybrid (zamba2): shared attention block applied every N backbone layers
    shared_attn_every: int = 0
    # encdec (whisper): encoder layers; n_layers counts decoder layers
    n_encoder_layers: int = 0
    encoder_context: int = 1500   # whisper 30s window frames
    # dense first-k layers for MoE models (kimi-k2 layer 0 is dense)
    moe_first_dense: int = 0
    # modality frontend stub: inputs are precomputed embeddings, not ids
    frontend_stub: bool = False
    max_seq_len: int = 1 << 20

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        attn = self.attn
        if attn is not None:
            attn = dataclasses.replace(
                attn,
                num_heads=max(2, min(4, attn.num_heads)),
                num_kv_heads=2 if attn.num_kv_heads > 1 else 1,
                head_dim=16,
                window=64 if attn.window else None,
                global_every=attn.global_every if attn.global_every <= 3 else 3,
            )
        moe = self.moe
        if moe is not None:
            moe = dataclasses.replace(
                moe, num_experts=4, top_k=2, d_ff_expert=64,
                num_shared_experts=min(1, moe.num_shared_experts),
                d_ff_shared=64 if moe.num_shared_experts else 0)
        ssm = self.ssm
        if ssm is not None:
            ssm = dataclasses.replace(
                ssm, state_dim=16, head_dim=16, conv_width=4, chunk_size=32)
        n_layers = min(self.n_layers, 4 if self.family != "hybrid" else 7)
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=n_layers,
            d_model=64,
            d_ff=128,
            vocab_size=256,
            attn=attn,
            moe=moe,
            ssm=ssm,
            kv_quant=dataclasses.replace(self.kv_quant, m_bytes=2,
                                         codebook_size=16),
            shared_attn_every=3 if self.shared_attn_every else 0,
            n_encoder_layers=min(self.n_encoder_layers, 2),
            encoder_context=32,
            moe_first_dense=min(self.moe_first_dense, 1),
            parallel=dataclasses.replace(
                self.parallel, param_dtype="float32",
                opt_state_dtype="float32", compute_dtype="float32",
                attn_chunk=64),
        )

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_pure_full_attention(self) -> bool:
        """True if every token-mixing layer is unwindowed full attention."""
        if self.family in ("ssm", "hybrid"):
            return False
        if self.attn is not None and self.attn.window is not None:
            return False
        return True


# ---------------------------------------------------------------------------
# Input shapes (assigned; identical across the 10 LM archs)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runnable, reason). long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and arch.is_pure_full_attention:
        return False, ("skip: pure full-attention arch; 524k decode context "
                       "requires sub-quadratic attention (DESIGN.md §5)")
    return True, ""
