"""--arch id -> ArchConfig registry + per-arch config modules."""
from __future__ import annotations

from typing import Dict

from repro.configs import archs
from repro.configs.base import ArchConfig

_REGISTRY: Dict[str, ArchConfig] = {a.name: a for a in archs.ALL_ARCHS}

# also accept filesystem-friendly ids (dots/dashes)
_ALIASES = {
    "mamba2_1_3b": "mamba2-1.3b",
    "chameleon_34b": "chameleon-34b",
    "kimi_k2_1t_a32b": "kimi-k2-1t-a32b",
    "dbrx_132b": "dbrx-132b",
    "deepseek_coder_33b": "deepseek-coder-33b",
    "mistral_large_123b": "mistral-large-123b",
    "gemma3_12b": "gemma3-12b",
    "qwen2_5_32b": "qwen2.5-32b",
    "whisper_tiny": "whisper-tiny",
    "zamba2_1_2b": "zamba2-1.2b",
}


def get_arch(name: str) -> ArchConfig:
    name = _ALIASES.get(name, name)
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs():
    return sorted(_REGISTRY)
