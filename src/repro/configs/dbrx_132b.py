from repro.configs.registry import get_arch

CONFIG = get_arch("dbrx_132b")
