from repro.configs.registry import get_arch

CONFIG = get_arch("chameleon_34b")
