from repro.configs.registry import get_arch

CONFIG = get_arch("whisper_tiny")
