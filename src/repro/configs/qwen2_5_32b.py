from repro.configs.registry import get_arch

CONFIG = get_arch("qwen2_5_32b")
