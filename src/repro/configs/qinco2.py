"""QINCo2 model configs (the paper's own architecture, Table 2)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class QincoConfig:
    name: str
    d: int = 128                 # data dimension (BigANN default)
    de: int = 384                # embedding (backbone) dim
    dh: int = 384                # hidden dim of residual MLPs
    L: int = 16                  # residual blocks in f_theta
    Ls: int = 0                  # residual blocks in g_phi (0 = plain codebook)
    M: int = 8                   # quantization steps (bytes at K=256)
    K: int = 256                 # codebook size per step
    A_train: int = 16            # pre-selected candidates during training
    B_train: int = 32            # beam size during training
    A_eval: int = 32
    B_eval: int = 64
    # training recipe (paper App. A.2)
    lr: float = 8e-4
    min_lr_ratio: float = 1e-3
    weight_decay: float = 0.1
    grad_clip: float = 0.1
    batch_size: int = 8192
    epochs: int = 70
    codebook_init_noise: float = 0.025
    kmeans_init_iters: int = 10
    qinco1_mode: bool = False    # original QINCo: de=d, no extra projections


def qinco2_s(**kw) -> QincoConfig:
    return QincoConfig(name="qinco2-s", L=2, de=128, dh=256, **kw)


def qinco2_m(**kw) -> QincoConfig:
    return QincoConfig(name="qinco2-m", L=4, de=384, dh=384, **kw)


def qinco2_l(**kw) -> QincoConfig:
    return QincoConfig(name="qinco2-l", L=16, de=384, dh=384, **kw)


def qinco1(**kw) -> QincoConfig:
    """QINCo baseline (Huijben et al. 2024): greedy, d_e = d, Adam-era arch."""
    d = kw.pop("d", 128)
    return QincoConfig(name="qinco1", L=2, de=d, dh=256, d=d,
                       A_train=256, B_train=1, A_eval=256, B_eval=1,
                       qinco1_mode=True, **kw)


def tiny(**kw) -> QincoConfig:
    """CPU-budget config for tests/benches."""
    defaults = dict(name="qinco2-tiny", d=16, de=24, dh=32, L=1, M=4, K=16,
                    A_train=4, B_train=4, A_eval=8, B_eval=8,
                    batch_size=256, epochs=3)
    defaults.update(kw)
    return QincoConfig(**defaults)


PRESETS = {
    "qinco2-s": qinco2_s,
    "qinco2-m": qinco2_m,
    "qinco2-l": qinco2_l,
    "qinco1": qinco1,
    "qinco2-tiny": tiny,
}
