from repro.configs.registry import get_arch

CONFIG = get_arch("mamba2_1_3b")
