"""The 10 assigned architectures (exact public-literature configs).

Each also exists as ``configs/<id>.py`` exporting ``CONFIG`` for the
``--arch <id>`` CLI convention; this module is the single source of truth.
"""
from __future__ import annotations

from repro.configs.base import (
    ArchConfig, AttnConfig, KVQuantConfig, MoEConfig, ParallelPolicy, SSMConfig,
)

# -- [ssm] SSD (state-space duality)  [arXiv:2405.21060] ---------------------
MAMBA2_1_3B = ArchConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, d_ff=0, vocab_size=50280,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4,
                  chunk_size=256),
    parallel=ParallelPolicy(fsdp=False, remat_policy="dots",
                            grad_compress_pods=True),
)

# -- [vlm] early-fusion, VQ image tokens  [arXiv:2405.09818] -----------------
CHAMELEON_34B = ArchConfig(
    name="chameleon-34b", family="dense",
    n_layers=48, d_model=8192, d_ff=22016, vocab_size=65536,
    attn=AttnConfig(num_heads=64, num_kv_heads=8, head_dim=128),
    frontend_stub=True,   # VQ image tokenizer stub: input_specs gives embeds
    kv_quant=KVQuantConfig(enabled=True, m_bytes=4),
    parallel=ParallelPolicy(fsdp=True, grad_compress_pods=True),
)

# -- [moe] Kimi K2 trillion-param MoE  [arXiv:2501.kimi2] --------------------
KIMI_K2_1T = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, d_ff=18432, vocab_size=163840,
    attn=AttnConfig(num_heads=64, num_kv_heads=8, head_dim=112),
    moe=MoEConfig(num_experts=384, top_k=8, d_ff_expert=2048,
                  num_shared_experts=1, d_ff_shared=2048),
    moe_first_dense=1,
    kv_quant=KVQuantConfig(enabled=True, m_bytes=4),
    parallel=ParallelPolicy(
        fsdp=True, expert_parallel=True, remat_policy="full",
        param_dtype="bfloat16", opt_state_dtype="bfloat16",
        grad_compress_pods=True),
)

# -- [moe] DBRX 16 experts top-4  [hf:databricks/dbrx-base] ------------------
DBRX_132B = ArchConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, d_ff=10752, vocab_size=100352,
    attn=AttnConfig(num_heads=48, num_kv_heads=8, head_dim=128),
    moe=MoEConfig(num_experts=16, top_k=4, d_ff_expert=10752),
    kv_quant=KVQuantConfig(enabled=True, m_bytes=4),
    parallel=ParallelPolicy(fsdp=True, expert_parallel=True,
                            remat_policy="full", grad_compress_pods=True),
)

# -- [dense] llama-arch  [arXiv:2401.14196] ----------------------------------
DEEPSEEK_CODER_33B = ArchConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, d_ff=19200, vocab_size=32256,
    attn=AttnConfig(num_heads=56, num_kv_heads=8, head_dim=128),
    kv_quant=KVQuantConfig(enabled=True, m_bytes=4),
    parallel=ParallelPolicy(fsdp=True, grad_compress_pods=True),
)

# -- [dense]  [hf:mistralai/Mistral-Large-Instruct-2407] ---------------------
MISTRAL_LARGE_123B = ArchConfig(
    name="mistral-large-123b", family="dense",
    n_layers=88, d_model=12288, d_ff=28672, vocab_size=32768,
    attn=AttnConfig(num_heads=96, num_kv_heads=8, head_dim=128),
    kv_quant=KVQuantConfig(enabled=True, m_bytes=4),
    parallel=ParallelPolicy(fsdp=True, remat_policy="full",
                            param_dtype="bfloat16",
                            opt_state_dtype="bfloat16",
                            grad_compress_pods=True),
)

# -- [dense] 5:1 local:global, 128k ctx  [hf:google/gemma-3] -----------------
GEMMA3_12B = ArchConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, d_ff=15360, vocab_size=262144,
    attn=AttnConfig(num_heads=16, num_kv_heads=8, head_dim=256,
                    window=1024, global_every=6, rope_theta=1_000_000.0),
    tie_embeddings=True,
    kv_quant=KVQuantConfig(enabled=True, m_bytes=4),
    parallel=ParallelPolicy(fsdp=True, grad_compress_pods=True),
)

# -- [dense] GQA, QKV bias  [hf:Qwen/Qwen2.5] --------------------------------
QWEN2_5_32B = ArchConfig(
    name="qwen2.5-32b", family="dense",
    n_layers=64, d_model=5120, d_ff=27648, vocab_size=152064,
    attn=AttnConfig(num_heads=40, num_kv_heads=8, head_dim=128,
                    qkv_bias=True),
    kv_quant=KVQuantConfig(enabled=True, m_bytes=4),
    parallel=ParallelPolicy(fsdp=True, grad_compress_pods=True),
)

# -- [audio] enc-dec, conv frontend (stub)  [arXiv:2212.04356] ---------------
WHISPER_TINY = ArchConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, n_encoder_layers=4, encoder_context=1500,
    d_model=384, d_ff=1536, vocab_size=51865,
    attn=AttnConfig(num_heads=6, num_kv_heads=6, head_dim=64),
    frontend_stub=True,   # conv frontend stub: inputs are frame embeddings
    kv_quant=KVQuantConfig(enabled=True, m_bytes=2),
    parallel=ParallelPolicy(fsdp=False),
)

# -- [hybrid] Mamba2 + shared attn blocks  [arXiv:2411.15242] ----------------
ZAMBA2_1_2B = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, d_ff=8192, vocab_size=32000,
    attn=AttnConfig(num_heads=32, num_kv_heads=32, head_dim=64),
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4,
                  chunk_size=256),
    shared_attn_every=6,   # one *shared-weight* attn+MLP block per 6 layers
    kv_quant=KVQuantConfig(enabled=True, m_bytes=2),
    parallel=ParallelPolicy(fsdp=False, grad_compress_pods=True),
)

ALL_ARCHS = (
    MAMBA2_1_3B, CHAMELEON_34B, KIMI_K2_1T, DBRX_132B, DEEPSEEK_CODER_33B,
    MISTRAL_LARGE_123B, GEMMA3_12B, QWEN2_5_32B, WHISPER_TINY, ZAMBA2_1_2B,
)
