from repro.configs.registry import get_arch

CONFIG = get_arch("zamba2_1_2b")
