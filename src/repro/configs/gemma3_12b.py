from repro.configs.registry import get_arch

CONFIG = get_arch("gemma3_12b")
