"""Assemble EXPERIMENTS.md from the dry-run / perf JSON artifacts plus the
paper-claims validation results. Re-run after refreshing any artifacts:

    PYTHONPATH=src python -m benchmarks.make_experiments_md
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.launch.mesh import HW

DRY = Path("experiments/dryrun")
PERF = Path("experiments/perf")


def _load(p: Path):
    return json.loads(p.read_text())


def _gb(x):
    return f"{x / 1e9:.2f}"


def dryrun_section():
    lines = [
        "## §Dry-run — 40 (arch x shape) cells x {16x16, 2x16x16} meshes",
        "",
        "Every cell is `jax.jit(step, in_shardings, out_shardings)"
        ".lower(ShapeDtypeStructs).compile()` on placeholder CPU devices "
        "(`--xla_force_host_platform_device_count=512`). `train` lowers "
        "train_step (fwd+bwd+AdamW), `prefill` lowers serve-prefill, "
        "`decode`/`long` lower serve_step (1 new token over a seq_len KV "
        "cache). Collective bytes are parsed from the compiled per-device "
        "HLO with while-loop trip-count weighting "
        "(launch/hlo_analysis.py); byte models in that module's docstring.",
        "",
        "| arch | shape | mesh | status | params/dev | opt/dev | cache/dev |"
        " HLO flops/dev | wire GB/dev | collectives (count) | compile s |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    n_ok = n_skip = 0
    for p in sorted(DRY.glob("*.json")):
        r = _load(p)
        if r["arch"].startswith("qinco"):
            continue
        if "+" in r["arch"]:
            continue                      # perf variants live in §Perf
        if not r.get("runnable", True):
            n_skip += 1
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                f"SKIP ({r.get('skip_reason', '')[:48]}…) | — | — | — | — "
                f"| — | — | — |")
            continue
        if r.get("error"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR "
                f"{r['error'][:40]} | — | — | — | — | — | — | — |")
            continue
        n_ok += 1
        colls = ", ".join(f"{k}x{int(v['count'])}"
                          for k, v in sorted(r["collectives"].items()))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{_gb(r.get('param_bytes_per_device', 0))} | "
            f"{_gb(r.get('opt_bytes_per_device', 0))} | "
            f"{_gb(r.get('cache_bytes_per_device', 0))} | "
            f"{r['cost'].get('flops', 0):.3e} | "
            f"{_gb(r['collective_wire_bytes'])} | {colls} | "
            f"{r.get('compile_s', 0):.0f} |")
    lines.append("")
    lines.append(f"**{n_ok} cells compiled, {n_skip} recorded skips** "
                 "(long_500k on pure full-attention archs, DESIGN.md §5). "
                 "HLO flops/dev counts while-loop bodies once (XLA CPU "
                 "cost-analysis limitation) — the roofline section uses the "
                 "analytic model; wire bytes ARE trip-count corrected.")
    # paper's own workloads
    lines.append("")
    lines.append("### The paper's own workloads at the mesh (full-manual "
                 "shard_map; see §Perf Q-cell)")
    lines.append("")
    lines.append("| workload | mesh | t_compute | t_memory | t_collective |"
                 " bottleneck | collectives |")
    lines.append("|---|---|---|---|---|---|---|")
    for p in sorted(DRY.glob("qinco2*.json")):
        r = _load(p)
        if r.get("error"):
            continue
        colls = ", ".join(f"{k}x{int(v['count'])}={_gb(v['wire_bytes'])}GB"
                          for k, v in sorted(r["collectives"].items()))
        lines.append(
            f"| {r['arch']} {r['shape']} | {r['mesh']} | "
            f"{r['t_compute_s']:.4f} | {r['t_memory_s']:.6f} | "
            f"{r['t_collective_s']:.4f} | {r['bottleneck']} | {colls} |")
    return "\n".join(lines)


def roofline_section():
    lines = [
        "## §Roofline — per (arch x shape), single-pod 16x16 mesh",
        "",
        "Terms from the analytic per-device model (launch/analytic.py — "
        "mirrors the exact einsums; XLA-CPU cost analysis undercounts "
        "scanned loops and promotes bf16 collectives, so compiled numbers "
        "serve as structural cross-checks). Constants: "
        f"{HW['peak_flops_bf16']/1e12:.0f} TF/s bf16, "
        f"{HW['hbm_bw']/1e9:.0f} GB/s HBM, {HW['ici_bw']/1e9:.0f} GB/s ICI, "
        f"{HW['dcn_bw']/1e9:.2f} GB/s DCN.",
        "",
        "roofline_frac = t_compute / max(terms) (1.0 = compute-bound at "
        "perfect overlap). mf_ratio = MODEL_FLOPS(6ND | 6N_aD) / analytic "
        "HLO-equivalent flops — the useful-compute fraction; <1 from remat "
        "recompute, full-context masked attention, and head-padding waste.",
        "",
        "| arch | shape | t_compute s | t_memory s | t_collective s | "
        "bottleneck | frac | mf_ratio | HBM fit | one-line fix |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    fixes = {
        ("train", "collective"): "fewer TP ARs (parallel block) / DP-only "
                                 "for small archs — see §Perf",
        ("train", "compute"): "near roofline; overlap AG w/ compute",
        ("prefill", "collective"): "same TP-AR levers as train",
        ("decode", "memory"): "RQ KV-cache compression (paper technique, "
                              "§Perf C-cell) + bf16 weights",
        ("decode", "collective"): "serving layout (params TP-sharded, no "
                                  "FSDP at decode) then KV-quant — §Perf C",
    }
    for p in sorted(DRY.glob("*.json")):
        r = _load(p)
        if r["arch"].startswith("qinco") or "+" in r["arch"]:
            continue
        if r["mesh"] != "16x16":
            continue
        if not r.get("runnable", True):
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | skip "
                         f"| — | — | — | {r.get('skip_reason','')[:44]} |")
            continue
        if r.get("error"):
            continue
        am = r["analytic"]
        fit = am.get("note_hbm_fit_bytes", 0) <= HW["hbm_bytes"]
        kind = ("decode" if r["shape"].startswith(("decode", "long"))
                else ("prefill" if r["shape"].startswith("prefill")
                      else "train"))
        fix = fixes.get((kind, r["bottleneck"]), "")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.4f} | "
            f"{r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} | "
            f"{r['bottleneck']} | {r.get('roofline_fraction', 0):.2f} | "
            f"{r.get('model_hlo_ratio', 0):.2f} | "
            f"{'Y' if fit else 'N'} | {fix} |")
    return "\n".join(lines)


def perf_section():
    lines = [
        "## §Perf — hypothesis -> change -> re-lower -> re-analyse",
        "",
        "Three cells picked per the brief (worst roofline fraction, most "
        "collective-bound, most paper-representative) + the multi-pod DCN "
        "cell. Every variant is a real config change re-compiled at the "
        "production mesh; records in experiments/perf/.",
        "",
    ]
    from repro.launch import perf as perf_mod
    titles = {
        "mamba2_train": "A. mamba2-1.3b x train_4k — worst roofline "
                        "fraction (0.10): wrong parallelism for a 1.3B",
        "kimi_train": "B. kimi-k2-1t-a32b x train_4k — most "
                      "collective-bound (t_coll 16.4 s)",
        "kimi_train_pod2": "D. kimi-k2-1t-a32b x train_4k @ 2x16x16 — "
                           "cross-pod DCN gradient exchange",
        "deepseek_decode": "C. deepseek-coder-33b x decode_32k — the "
                           "paper's technique (RQ KV cache)",
        "chameleon_prefill": "E. chameleon-34b x prefill_32k — bonus "
                             "ladder: prefill has the same TP/FSDP levers",
    }
    for cell in ("mamba2_train", "kimi_train", "deepseek_decode",
                 "kimi_train_pod2", "chameleon_prefill"):
        shape = perf_mod.CELL_SHAPES[cell]
        mp = perf_mod.CELL_PODS.get(cell, False)
        rows = []
        for name, hypothesis, arch_fn, kvq in perf_mod._variants()[cell]:
            tag = (f"{arch_fn().name}+{name}__{shape}__"
                   f"{'pod2' if mp else 'pod1'}")
            if kvq:
                tag += "__kvq"
            p = PERF / f"{tag}.json"
            if not p.exists():
                continue
            r = _load(p)
            r["variant"] = name
            r["hypothesis"] = hypothesis
            rows.append(r)
        lines.append(f"### {titles[cell]}")
        lines.append("")
        lines.append("| variant | hypothesis | t_comp | t_mem | t_coll | "
                     "frac | verdict |")
        lines.append("|---|---|---|---|---|---|---|")
        prev = None
        for r in rows:
            if r.get("error"):
                lines.append(f"| {r.get('variant','?')} | "
                             f"{r.get('hypothesis','')[:90]} | — | — | — | "
                             f"— | ERROR |")
                continue
            frac = r.get("roofline_fraction", 0)
            verdict = "baseline"
            if prev is not None:
                bound_prev = max(prev["t_compute_s"], prev["t_memory_s"],
                                 prev["t_collective_s"])
                bound = max(r["t_compute_s"], r["t_memory_s"],
                            r["t_collective_s"])
                if bound < bound_prev * 0.95:
                    verdict = (f"CONFIRMED: step bound "
                               f"{bound_prev:.3f}->{bound:.3f}s "
                               f"({bound_prev/bound:.1f}x)")
                elif abs(bound - bound_prev) <= bound_prev * 0.05:
                    verdict = "REFUTED: bound unchanged (see notes)"
                else:
                    verdict = "REGRESSION"
            lines.append(
                f"| {r.get('variant','?')} | "
                f"{r.get('hypothesis','')[:90]} | "
                f"{r['t_compute_s']:.4f} | {r['t_memory_s']:.4f} | "
                f"{r['t_collective_s']:.4f} | {frac:.2f} | {verdict} |")
            prev = r
        lines.append("")
    return "\n".join(lines)


def main():
    parts = [dryrun_section(), "", roofline_section(), "", perf_section()]
    out = "\n".join(parts)
    print(out)
    return out


if __name__ == "__main__":
    main()
