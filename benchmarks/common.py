"""Shared benchmark utilities: datasets, metrics, timing."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import training
from repro.data.synthetic import make_splits


def bench_data(name="bigann", *, dim=24, n_train=6000, n_db=4000,
               n_query=128, seed=0):
    """Reduced-dim stand-in splits, z-normalized with the train stats."""
    xt, xb, xq, _ = make_splits(name, n_train=n_train, n_db=n_db,
                                n_query=n_query, seed=seed)
    xt = xt[:, :dim]
    xb = xb[:, :dim]
    xq = xq[:, :dim]
    xt, (mu, sd) = training.normalize_dataset(xt)
    xb = ((xb - mu) / sd).astype(np.float32)
    xq = ((xq - mu) / sd).astype(np.float32)
    gt = np.argmin(((xq[:, None] - xb[None]) ** 2).sum(-1), axis=1)
    return xt, xb, xq, gt


def mse(x, xhat) -> float:
    return float(jnp.mean(jnp.sum((jnp.asarray(x) - xhat) ** 2, -1)))


def recall_at(ids, gt, k=1) -> float:
    ids = np.asarray(ids)[:, :k]
    return float((ids == np.asarray(gt)[:, None]).any(1).mean())


def timeit_us(fn, *args, reps=5, warmup=1, min_total_s=0.25,
              max_reps=200) -> float:
    """Best (min) wall time in microseconds (after jit warmup).

    Min-of-N, not median: wall-clock noise on a shared CI machine is
    strictly additive (scheduler stalls, GC), so the minimum is the
    stable estimator of the true cost — a median-of-3 lets ONE stalled
    rep swing sub-millisecond rows by multiples, which is exactly what
    the `scripts/check_bench.py` regression gate must not see. Reps are
    adaptive: at least ``reps``, and for cheap calls as many as fit in
    ``min_total_s`` (capped at ``max_reps``) — a 300us kernel gets ~200
    chances to land in a load gap for ~60ms of bench time, while
    multi-second rows keep exactly ``reps``."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    t_acc = 0.0
    while len(ts) < reps or (t_acc < min_total_s and len(ts) < max_reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        dt = time.perf_counter() - t0
        ts.append(dt * 1e6)
        t_acc += dt
    return float(np.min(ts))


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.2f},{derived}", flush=True)
