"""Shared benchmark utilities: datasets, metrics, timing."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import training
from repro.data.synthetic import make_splits


def bench_data(name="bigann", *, dim=24, n_train=6000, n_db=4000,
               n_query=128, seed=0):
    """Reduced-dim stand-in splits, z-normalized with the train stats."""
    xt, xb, xq, _ = make_splits(name, n_train=n_train, n_db=n_db,
                                n_query=n_query, seed=seed)
    xt = xt[:, :dim]
    xb = xb[:, :dim]
    xq = xq[:, :dim]
    xt, (mu, sd) = training.normalize_dataset(xt)
    xb = ((xb - mu) / sd).astype(np.float32)
    xq = ((xq - mu) / sd).astype(np.float32)
    gt = np.argmin(((xq[:, None] - xb[None]) ** 2).sum(-1), axis=1)
    return xt, xb, xq, gt


def mse(x, xhat) -> float:
    return float(jnp.mean(jnp.sum((jnp.asarray(x) - xhat) ** 2, -1)))


def recall_at(ids, gt, k=1) -> float:
    ids = np.asarray(ids)[:, :k]
    return float((ids == np.asarray(gt)[:, None]).any(1).mean())


def timeit_us(fn, *args, reps=3, warmup=1) -> float:
    """Median wall time in microseconds (after jit warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.2f},{derived}", flush=True)
