"""Encode/search throughput: the `kernels/ops` dispatch backends compared
(xla reference path vs pallas kernels) on the paper hot loops — beam-search
encoding (§3.2), the fused f_theta step network it runs A*B times per
vector per step, ADC/pairwise candidate scoring (§3.3), the fused
adc_topk shortlist, and the full-decode re-rank (Fig. 3 step 4).

On TPU the pallas column is the native-kernel path; on CPU it runs in
interpret mode (expected to be much slower — the column is then a
correctness/coverage signal, not a speed claim; every row records which
mode was measured). `main(json_path=...)` writes the rows as
machine-readable JSON so the perf trajectory has data points
(`benchmarks/run.py --only backends` -> BENCH_kernels.json).
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_data, timeit_us
from repro.configs.qinco2 import tiny
from repro.core import encode as enc
from repro.core import qinco, training
from repro.kernels import ops

BACKENDS = ("xla", "pallas")


def run(dim=16, M=4, K=16, n_db=2048, n_q=32, seed=0, *,
        backends=BACKENDS, reps=3):
    xt, xb, xq, _ = bench_data("bigann", dim=dim, n_db=n_db, n_query=n_q,
                               seed=seed)
    cfg = tiny(d=dim, M=M, K=K, epochs=1, batch_size=512)
    params = training.init_qinco2(jax.random.key(seed), xt, cfg)
    xbj = jnp.asarray(xb[:512])
    mode = "native" if jax.default_backend() == "tpu" else "interpret"

    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, K, size=(n_db, M)).astype(np.int32))
    lut = jnp.asarray(rng.normal(size=(n_q, M, K)).astype(np.float32))
    norms = jnp.asarray((rng.normal(size=(n_db,)) ** 2).astype(np.float32))
    pairs = tuple((i, (i + 1) % M) for i in range(M))
    plut = jnp.asarray(
        rng.normal(size=(n_q, len(pairs), K * K)).astype(np.float32))
    r = jnp.asarray(rng.normal(size=(1024, dim)).astype(np.float32))
    cb = params["pre_codebooks"][0]
    fm = qinco.step_params_at(params, 0)
    fcb = params["codebooks"][0]
    # fused-op shapes: a beam-expansion tile and a re-rank decode batch
    n_f = 1024
    c_rows = jnp.asarray(rng.normal(size=(n_f, dim)).astype(np.float32))
    x_rows = jnp.asarray(rng.normal(size=(n_f, dim)).astype(np.float32))
    eidx = jnp.asarray(
        rng.integers(0, K, size=(256, 8)).astype(np.int32))   # (NB, A)
    ex = jnp.asarray(rng.normal(size=(256, dim)).astype(np.float32))
    dcodes = codes[:512]
    # fused beam-step shapes: one full (N, B, A) expansion + pre-selection
    bB, bA = 4, 8
    bxh = jnp.asarray(rng.normal(size=(128, bB, dim)).astype(np.float32))
    bidx = jnp.asarray(
        rng.integers(0, K, size=(128, bB, bA)).astype(np.int32))
    bx = jnp.asarray(rng.normal(size=(128, dim)).astype(np.float32))
    berr = jnp.asarray((rng.normal(size=(128, bB)) ** 2).astype(np.float32))
    pxh = jnp.asarray(rng.normal(size=(512, dim)).astype(np.float32))
    pre = jnp.asarray(rng.normal(size=(512, dim)).astype(np.float32))

    rows = []

    def add(op, be, t_us, n):
        rows.append({"op": op, "backend": be,
                     "mode": mode if be == "pallas" else "-",
                     "us_per_vec": t_us / n})

    for be in backends:
        t = timeit_us(lambda x: enc.encode(params, x, cfg, 8, 8,
                                           backend=be)[0], xbj, reps=reps)
        add("encode(A=8,B=8)", be, t, len(xbj))
        t = timeit_us(lambda rr: ops.l2_topk(rr, cb, 8, backend=be)[0], r,
                      reps=reps)
        add("l2_topk(A=8)", be, t, len(r))
        t = timeit_us(lambda cc, xx: ops.f_theta(fm, cc, xx, backend=be),
                      c_rows, x_rows, reps=reps)
        add(f"f_theta({n_f})", be, t, n_f)
        t = timeit_us(lambda ii, xx: ops.f_theta(fm, fcb, xx, idx=ii,
                                                 backend=be),
                      eidx, ex, reps=reps)
        add("f_theta_gather(256x8)", be, t, eidx.shape[0] * eidx.shape[1])
        t = timeit_us(lambda ii, xx: ops.f_theta_err(fm, fcb, bxh, ii, xx,
                                                     berr, backend=be)[0],
                      bidx, bx, reps=reps)
        add(f"f_theta_err(128x{bB}x{bA})", be, t, 128 * bB * bA)
        t = timeit_us(lambda xx, rr: ops.preselect_topk(fm, cb, xx, rr, 8,
                                                        backend=be)[0],
                      pxh, pre, reps=reps)
        add("preselect_topk(512,A=8)", be, t, 512)
        t = timeit_us(lambda c: qinco.decode(params, c, cfg, backend=be),
                      dcodes, reps=reps)
        add(f"decode({len(dcodes)})", be, t, len(dcodes))
        t = timeit_us(lambda c: ops.adc_scores(c, lut, norms=norms,
                                               backend=be), codes, reps=reps)
        add(f"adc_scores({n_q}x{n_db})", be, t, n_db)
        t = timeit_us(lambda c: ops.adc_topk(c, lut, 16, norms=norms,
                                             backend=be)[0], codes,
                      reps=reps)
        add(f"adc_topk({n_q}x{n_db},k=16)", be, t, n_db)
        t = timeit_us(lambda c: ops.pairwise_scores(c, plut, pairs, K,
                                                    backend=be), codes,
                      reps=reps)
        add(f"pairwise_scores({n_q}x{n_db})", be, t, n_db)
    return rows


def main(fast=True, json_path=None):
    rows = run(n_db=1024 if fast else 8192, reps=2 if fast else 5)
    print("op,backend,mode,us_per_vec")
    for r in rows:
        print(f"{r['op']},{r['backend']},{r['mode']},"
              f"{r['us_per_vec']:.3f}")
    if json_path is not None:
        with open(json_path, "w") as f:
            json.dump({"device": jax.default_backend(), "rows": rows}, f,
                      indent=2)
        print(f"[kernel_backends] wrote {json_path}")
    return rows


if __name__ == "__main__":
    main(fast=False, json_path="BENCH_kernels.json")
