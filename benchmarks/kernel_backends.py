"""Encode/search throughput: the `kernels/ops` dispatch backends compared
(xla reference path vs pallas kernels) on the two paper hot loops —
beam-search encoding (§3.2) and ADC/pairwise candidate scoring (§3.3).

On TPU the pallas column is the native-kernel path; on CPU it runs in
interpret mode (expected to be much slower — the column is then a
correctness/coverage signal, not a speed claim; the printed rows say which
mode was measured).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_data, timeit_us
from repro.configs.qinco2 import tiny
from repro.core import encode as enc
from repro.core import training
from repro.kernels import ops

BACKENDS = ("xla", "pallas")


def run(dim=16, M=4, K=16, n_db=2048, n_q=32, seed=0, *,
        backends=BACKENDS, reps=3):
    xt, xb, xq, _ = bench_data("bigann", dim=dim, n_db=n_db, n_query=n_q,
                               seed=seed)
    cfg = tiny(d=dim, M=M, K=K, epochs=1, batch_size=512)
    params = training.init_qinco2(jax.random.key(seed), xt, cfg)
    xbj = jnp.asarray(xb[:512])
    mode = "native" if jax.default_backend() == "tpu" else "interpret"

    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, K, size=(n_db, M)).astype(np.int32))
    lut = jnp.asarray(rng.normal(size=(n_q, M, K)).astype(np.float32))
    norms = jnp.asarray((rng.normal(size=(n_db,)) ** 2).astype(np.float32))
    pairs = tuple((i, (i + 1) % M) for i in range(M))
    plut = jnp.asarray(
        rng.normal(size=(n_q, len(pairs), K * K)).astype(np.float32))
    r = jnp.asarray(rng.normal(size=(1024, dim)).astype(np.float32))
    cb = params["pre_codebooks"][0]

    rows = []
    for be in backends:
        tag = f"{be}" if be == "xla" else f"{be}/{mode}"
        t = timeit_us(lambda x: enc.encode(params, x, cfg, 8, 8,
                                           backend=be)[0], xbj, reps=reps)
        rows.append({"op": "encode(A=8,B=8)", "backend": tag,
                     "us_per_vec": t / len(xbj)})
        t = timeit_us(lambda rr: ops.l2_topk(rr, cb, 8, backend=be)[0], r,
                      reps=reps)
        rows.append({"op": "l2_topk(A=8)", "backend": tag,
                     "us_per_vec": t / len(r)})
        t = timeit_us(lambda c: ops.adc_scores(c, lut, norms=norms,
                                               backend=be), codes, reps=reps)
        rows.append({"op": f"adc_scores({n_q}x{n_db})", "backend": tag,
                     "us_per_vec": t / n_db})
        t = timeit_us(lambda c: ops.pairwise_scores(c, plut, pairs, K,
                                                    backend=be), codes,
                      reps=reps)
        rows.append({"op": f"pairwise_scores({n_q}x{n_db})", "backend": tag,
                     "us_per_vec": t / n_db})
    return rows


def main(fast=True):
    rows = run(n_db=1024 if fast else 8192, reps=2 if fast else 5)
    print("op,backend,us_per_vec")
    for r in rows:
        print(f"{r['op']},{r['backend']},{r['us_per_vec']:.3f}")
    return rows


if __name__ == "__main__":
    main(fast=False)
