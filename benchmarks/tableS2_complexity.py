"""Table S2: encoding/decoding complexity — analytic FLOPs per vector from
the paper's big-O formulas with our configs, plus measured per-vector CPU
timings (indicative only; the paper's table is also CPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_data, emit, timeit_us
from repro.configs.qinco2 import tiny
from repro.core import encode as enc
from repro.core import qinco, rq, training
from repro.kernels import ops


def flops_formulas(d, K, M, L, de, dh, A, B):
    return {
        "OPQ": {"enc": d * d + K * d, "dec": d * (d + 1)},
        "RQ(B=4)": {"enc": K * M * d * 4, "dec": M * d},
        "QINCo": {"enc": K * M * d * (d + L * dh), "dec": M * d * (d + L * dh)},
        "QINCo2": {"enc": A * B * M * de * (d + L * dh) + B * K * d,
                   "dec": M * de * (d + L * dh)},
    }


def run(dim=24, M=4, K=16, seed=0, n=2048):
    xt, xb, xq, gt = bench_data("bigann", dim=dim, n_db=n, seed=seed)
    cfg = tiny(d=dim, M=M, K=K, de=32, dh=48, L=2, A_train=4, B_train=8,
               A_eval=8, B_eval=8, epochs=1, batch_size=512)
    params, _ = training.train(jax.random.key(seed), xt[:1024], cfg,
                               verbose=False)
    xbj = jnp.asarray(xb)
    rows = []

    # RQ
    cbs = rq.rq_train(jax.random.key(0), jnp.asarray(xt[:1024]), M, K)
    t_enc = timeit_us(lambda x: rq.rq_encode(cbs, x, B=4)[0], xbj) / n
    codes, _ = rq.rq_encode(cbs, xbj, B=4)
    t_dec = timeit_us(lambda c: rq.rq_decode(cbs, c), codes) / n
    rows.append(("RQ(B=4)", t_enc, t_dec))

    # QINCo (greedy exhaustive on same params)
    t_enc = timeit_us(lambda x: enc.encode(params, x, cfg, K, 1)[0], xbj) / n
    qcodes, _, _ = enc.encode(params, xbj, cfg, cfg.A_eval, cfg.B_eval)
    t_dec = timeit_us(lambda c: qinco.decode(params, c, cfg), qcodes) / n
    rows.append(("QINCo(A=K,B=1)", t_enc, t_dec))

    # QINCo2 (pre-selection + beam)
    t_enc = timeit_us(lambda x: enc.encode(params, x, cfg, 8, 8)[0], xbj) / n
    rows.append(("QINCo2(A=8,B=8)", t_enc, t_dec))

    # Pallas kernel path for the pre-selection distance scan
    r = xbj
    cb0 = params["pre_codebooks"][0]
    t_pre = timeit_us(lambda x: ops.l2_topk(x, cb0, 8,
                                            backend="pallas")[0], r) / n
    rows.append(("l2_topk kernel (per step)", t_pre, 0.0))

    f = flops_formulas(dim, K, M, cfg.L, cfg.de, cfg.dh, 8, 8)
    return rows, f


def main(fast=True):
    rows, f = run(n=1024 if fast else 4096)
    print("method,encode_us_per_vec,decode_us_per_vec")
    for name, te, td in rows:
        print(f"{name},{te:.2f},{td:.2f}")
    print("method,flops_encode,flops_decode")
    for k, v in f.items():
        print(f"{k},{v['enc']:.0f},{v['dec']:.0f}")
    return rows


if __name__ == "__main__":
    main(fast=False)
