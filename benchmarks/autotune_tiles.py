"""`tuning.autotune` sweep -> a committed tile-table artifact.

Produces the first checked-in tile-table artifact
(`benchmarks/tile_tables/interpret_cpu.json`): a grid sweep over the
fused-op tile sizes — including the beam-step ops `f_theta_err` and
`preselect_topk` — with the winners written into the live table and the
WHOLE table persisted via `tuning.save` (so the artifact is loadable by
`serve_search --tile-table` and `StreamingIndexBuilder(tile_table=)`).

On CPU the sweep runs the kernels in interpret mode: the numbers rank
interpreter overhead, not MXU behavior, so the artifact is a format/
plumbing fixture and a template — a native-TPU run of this same script
(`python -m benchmarks.autotune_tiles --out tile_tables/tpu_v4.json`)
produces the real thing. The artifact records its provenance in the ops
it covers; `tuning.load` validates every entry before applying any.
"""
from __future__ import annotations

import argparse
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit_us
from repro.configs.qinco2 import tiny
from repro.core import qinco, training
from repro.kernels import ops, tuning


def sweep(fast=True, verbose=True):
    """Autotune the pallas ops the encode/search hot paths launch.
    Returns {op: {"best": ..., "results": [...]}} and leaves the winners
    in the live tuning table."""
    dim, M, K = 16, 4, 16
    seed = 0
    rng = np.random.default_rng(seed)
    cfg = tiny(d=dim, M=M, K=K, epochs=1, batch_size=256)
    x0 = jnp.asarray(rng.normal(size=(512, dim)).astype(np.float32))
    params = training.init_qinco2(jax.random.key(seed), x0, cfg)
    fm = qinco.step_params_at(params, 0)
    fcb = params["codebooks"][0]
    pcb = params["pre_codebooks"][0]

    n = 256 if fast else 2048
    reps = 2 if fast else 5
    B, A = 4, 8
    xh = jnp.asarray(rng.normal(size=(n, B, dim)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, K, size=(n, B, A)).astype(np.int32))
    xt = jnp.asarray(rng.normal(size=(n, dim)).astype(np.float32))
    err = jnp.asarray((rng.normal(size=(n, B)) ** 2).astype(np.float32))
    r = jnp.asarray(rng.normal(size=(n, dim)).astype(np.float32))
    codes = jnp.asarray(rng.integers(0, K, size=(n, M)).astype(np.int32))
    lut = jnp.asarray(rng.normal(size=(8, M, K)).astype(np.float32))

    cands = (4, 8, 16) if fast else (4, 8, 16, 32)
    jobs = {
        "f_theta_err": ({"tile_n": cands}, lambda **kw: timeit_us(
            lambda ii: ops.f_theta_err(fm, fcb, xh, ii, xt, err,
                                       backend="pallas", **kw)[0],
            idx, reps=reps) * 1e-6),
        "preselect_topk": ({"tile_n": cands}, lambda **kw: timeit_us(
            lambda xx: ops.preselect_topk(fm, pcb, xx, r, A,
                                          backend="pallas", **kw)[0],
            xt, reps=reps) * 1e-6),
        "f_theta_gather": ({"tile_n": cands}, lambda **kw: timeit_us(
            lambda ii: ops.f_theta(fm, fcb, xt, idx=ii[:, 0],
                                   backend="pallas", **kw),
            idx, reps=reps) * 1e-6),
        "adc_topk": ({"tile_q": (4, 8), "tile_n": (64, 128)},
                     lambda **kw: timeit_us(
            lambda cc: ops.adc_topk(cc, lut, 8, backend="pallas", **kw)[0],
            codes, reps=reps) * 1e-6),
    }
    out = {}
    for op, (cand_grid, bench) in jobs.items():
        out[op] = tuning.autotune(op, cand_grid, bench, reps=1)
        if verbose:
            print(f"[autotune] {op}: best={out[op]['best']} over "
                  f"{len(out[op]['results'])} candidates", flush=True)
    return out


def main(out_path="benchmarks/tile_tables/interpret_cpu.json", fast=True):
    sweep(fast=fast)
    p = pathlib.Path(out_path)
    p.parent.mkdir(parents=True, exist_ok=True)
    tuning.save(p)
    print(f"[autotune] wrote {p} (device={jax.default_backend()})")
    return p


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="benchmarks/tile_tables/"
                                     "interpret_cpu.json")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    main(args.out, fast=not args.full)
