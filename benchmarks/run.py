"""Benchmark entry point: one section per paper table/figure.

``python -m benchmarks.run``            fast mode (CPU-budget sizes)
``python -m benchmarks.run --full``     larger sizes
``python -m benchmarks.run --only t3``  single section

Prints ``name,us_per_call,derived`` CSV lines per section plus each
section's own table."""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import emit


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    choices=[None, "t3", "t4", "s2", "f5", "f6", "roofline",
                             "backends", "encode", "index", "search"])
    args = ap.parse_args()
    fast = not args.full
    sections = {
        "t3": _t3, "t4": _t4, "s2": _s2, "f5": _f5, "f6": _f6,
        "roofline": _roof, "backends": _backends, "encode": _encode,
        "index": _index, "search": _search,
    }
    todo = [args.only] if args.only else list(sections)
    print("name,us_per_call,derived")
    for name in todo:
        t0 = time.time()
        try:
            derived = sections[name](fast)
            emit(f"bench/{name}", (time.time() - t0) * 1e6, derived)
        except Exception as e:  # keep the harness running
            emit(f"bench/{name}", (time.time() - t0) * 1e6,
                 f"ERROR:{type(e).__name__}:{e}")
            raise


def _t3(fast):
    from benchmarks import table3_compression as t3
    print("\n== Table 3: compression ladder ==")
    rows = t3.main(fast=fast)
    qinco2 = rows[-1]["mse"]
    rqm = [r for r in rows if r["method"] == "RQ"][0]["mse"]
    return f"qinco2_mse={qinco2:.5f};rq_mse={rqm:.5f};gain={1-qinco2/rqm:.2%}"


def _t4(fast):
    from benchmarks import table4_decoders as t4
    print("\n== Table 4: approximate decoders ==")
    rows = t4.main(fast=fast)
    opt = rows[-1]
    return (f"opt_pairs_r1={opt['r@1']:.4f};"
            f"short10={opt['r@1_short10']:.4f}")


def _s2(fast):
    from benchmarks import tableS2_complexity as s2
    print("\n== Table S2: complexity ==")
    rows = s2.main(fast=fast)
    return ";".join(f"{n}={te:.1f}us" for n, te, _ in rows[:3])


def _f5(fast):
    from benchmarks import fig5_pareto as f5
    print("\n== Fig 5: Pareto front ==")
    rows = f5.main(fast=fast)
    best = min(rows, key=lambda r: r["mse"])
    return f"best_mse={best['mse']:.5f}@L{best['L']}A{best['A']}B{best['B']}"


def _f6(fast):
    from benchmarks import fig6_search as f6
    print("\n== Fig 6: search QPS vs recall ==")
    rows = f6.main(fast=fast)
    q2 = [r for r in rows if r["method"] == "IVF-QINCo2"]
    best = max(q2, key=lambda r: r["r@1"])
    return f"best_r1={best['r@1']:.4f}@qps={best['qps']:.0f}"


def _backends(fast):
    from benchmarks import kernel_backends as kb
    print("\n== ops dispatch: xla vs pallas backends ==")
    rows = kb.main(fast=fast, json_path="BENCH_kernels.json")
    xla_enc = [r for r in rows
               if r["op"].startswith("encode") and r["backend"] == "xla"]
    fused = [r for r in rows
             if r["op"].startswith("f_theta(") and r["backend"] == "xla"]
    return (f"encode_xla={xla_enc[0]['us_per_vec']:.1f}us/vec;"
            f"f_theta_xla={fused[0]['us_per_vec']:.2f}us/vec;"
            f"json=BENCH_kernels.json")


def _encode(fast):
    from benchmarks import encode_throughput as et
    print("\n== encode throughput: fused vs unfused beam steps ==")
    rows = et.main(fast=fast, json_path="BENCH_encode.json")

    def vps(be, fused):               # the widest-beam row: most work,
        sel = [r for r in rows        # least relative timing noise
               if r["backend"] == be and r["fused"] == fused]
        return sel[-1]["vecs_per_s"]
    r_pallas = vps("pallas", True) / vps("pallas", False)
    r_xla = vps("xla", True) / vps("xla", False)
    return (f"beam_fused_over_unfused_pallas={r_pallas:.2f};"
            f"beam_fused_over_unfused_xla={r_xla:.2f};"
            f"json=BENCH_encode.json")


def _index(fast):
    from benchmarks import index_io
    print("\n== index store: build / bytes / load-to-first-query ==")
    rows = index_io.main(fast=fast)
    d = {r["metric"]: r["value"] for r in rows}
    return (f"build_vps={d['build_vecs_per_s']:.0f};"
            f"bytes_per_vec={d['disk_bytes_per_vec']:.1f};"
            f"load_ms={d['load_to_first_query_ms']:.0f}")


def _search(fast):
    from benchmarks import search_throughput as st
    print("\n== search throughput: resident vs out-of-core ==")
    rows = st.main(fast=fast, json_path="BENCH_search.json")
    res = [r for r in rows if r["mode"] == "resident"][0]
    ooc = [r for r in rows if r["mode"] == "out_of_core"]
    best = max(ooc, key=lambda r: r["qps"])
    return (f"resident_qps={res['qps']:.0f};"
            f"ooc_qps={best['qps']:.0f}@shards={best['n_shards']};"
            f"ooc_over_resident={best['qps'] / res['qps']:.2f};"
            f"json=BENCH_search.json")


def _roof(fast):
    from benchmarks import roofline as rf
    from pathlib import Path
    print("\n== Roofline (from dry-run artifacts) ==")
    d = Path("experiments/dryrun")
    if not d.exists():
        return "no-dryrun-artifacts"
    print(rf.report(d, single_pod_only=True))
    return f"cells={len(list(d.glob('*.json')))}"


if __name__ == "__main__":
    main()
