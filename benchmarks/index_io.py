"""Index persistence I/O: streaming build throughput, on-disk bytes per
vector, and load-to-first-query latency of the `repro.index` store —
the operational costs of the billion-scale layout (paper §3.3) that the
in-memory benchmarks never see.

Also reports the packed-vs-int32 HBM footprint of the code matrix and the
ADC scan throughput on the packed representation (the bytes the store
serves are the bytes the kernel consumes).
"""
from __future__ import annotations

import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_data, timeit_us
from repro.configs.qinco2 import tiny
from repro.core import aq, search, training
from repro.index import IndexStore, StreamingIndexBuilder
from repro.kernels import ops


def run(*, dim=16, M=4, K=16, n_db=4096, shard_size=1024, seed=0, reps=3):
    xt, xb, xq, _ = bench_data("bigann", dim=dim, n_db=n_db, n_query=32,
                               seed=seed)
    cfg = tiny(d=dim, M=M, K=K, epochs=1, batch_size=512)
    params = training.init_qinco2(jax.random.key(seed), xt, cfg)
    rows = []
    tmp = tempfile.mkdtemp(prefix="bench_index_io_")
    try:
        # -- streaming build throughput -----------------------------------
        builder = StreamingIndexBuilder(tmp, shard_size=shard_size,
                                        encode_chunk=1024)
        builder.prepare(jax.random.key(1), xb[:2048], params, cfg,
                        n_total=n_db, k_ivf=32, m_tilde=2, n_pair_books=6)
        t0 = time.perf_counter()
        build_done = builder.build(xb)
        dt = time.perf_counter() - t0
        assert build_done
        rows.append({"metric": "build_vecs_per_s", "value": n_db / dt})

        # -- bytes/vector on disk -----------------------------------------
        store = IndexStore(tmp)
        rows.append({"metric": "disk_bytes_per_vec",
                     "value": store.bytes_per_vector()})
        rows.append({"metric": "code_bytes_per_vec", "value": float(M)})

        # -- load-to-first-query ------------------------------------------
        t0 = time.perf_counter()
        idx = store.load()
        ids, _ = search.search(idx, jnp.asarray(xq[:8]), n_probe=4,
                               n_short_aq=32, n_short_pw=8, topk=1, cfg=cfg)
        jax.block_until_ready(ids)
        rows.append({"metric": "load_to_first_query_ms",
                     "value": (time.perf_counter() - t0) * 1e3})

        # -- packed vs int32 scan (HBM footprint + throughput) ------------
        lut = jnp.asarray(aq.adc_lut(idx.aq_books, jnp.asarray(xq[:16])))
        codes32 = idx.codes.astype(jnp.int32)
        rows.append({"metric": "hbm_codes_mb_uint8",
                     "value": idx.codes.nbytes / 2**20})
        rows.append({"metric": "hbm_codes_mb_int32",
                     "value": codes32.nbytes / 2**20})
        for name, c in (("uint8", idx.codes), ("int32", codes32)):
            t = timeit_us(lambda cc: ops.adc_scores(cc, lut, backend="xla"),
                          c, reps=reps)
            rows.append({"metric": f"adc_scan_us_per_kvec_{name}",
                         "value": t / n_db * 1e3})
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return rows


def main(fast=True):
    rows = run(n_db=2048 if fast else 16384,
               shard_size=512 if fast else 4096, reps=2 if fast else 5)
    print("metric,value")
    for r in rows:
        print(f"{r['metric']},{r['value']:.3f}")
    return rows


if __name__ == "__main__":
    main(fast=False)
