"""Search-side serving throughput: resident vs out-of-core, across shard
counts.

The search cascade is QINCo2's serving cost (paper §3.3 / Fig. 6); since
the out-of-core PR it can run either against a resident `SearchIndex`
(`search()`, one fused executable) or against a `ShardedIndexView`
(`search_sharded()`, per-shard `ops.adc_topk` + running merge, database
mmap'd on disk). This section builds one small store, times batched
queries through both paths — the out-of-core one at several shard counts
(more shards = more per-shard launches + merges against the same total
work, the steady-state serving trade) — and reports QPS plus per-batch
p50/p99 latency per row.

Out-of-core rows are the steady-state shape: the shard LRU is sized to
hold every shard, so after warmup the timings measure the scan/merge
overhead, not disk re-staging. At the LARGEST shard count two extra
cold-scan rows squeeze the staging pipeline itself: the pool holds only
half the shards, so every scan evicts and re-stages — mode
``out_of_core_cold`` runs the default prefetched pipeline (shard s+1
stages in the background while s is scanned; evictions replay from the
host cache of assembled shards), ``out_of_core_cold_nopf`` the same
budget with prefetch off (each stage is a synchronous stall). The gap
between the two is the latency the pipeline hides. Two more cold rows
(``out_of_core_cold_verify`` / ``_noverify``) disable the host cache so
every re-stage reassembles from the mmaps, and report the crc32
integrity-verification overhead on that worst-case path (informational —
verify-on is the serving default).

Two network rows (informational) serve the SAME resident index through
the socket front door (`repro.launch.serve_search.SearchFrontDoor`) and
drive it with `repro.launch.search_client`: ``net_closed`` is the
self-throttling baseline (one request in flight — throughput gated by
round-trip latency, the server never queues), ``net_open`` offers
Poisson arrivals at ~2x the closed-loop rate, which is the load shape
that actually exercises continuous batching, the bounded queue and the
shed/retry path; its ``metrics`` record how many requests were shed and
retried. qps counts query rows in both, so the framing + admission
overhead reads directly against the in-process ``resident`` row.

Four live-mutation rows (informational) measure the churn story:
``out_of_core_churn`` is steady-state QPS over a view carrying delta
shards + a tombstone bitmap, with recall@10 against exact float search
over the surviving vectors in its ``metrics`` (recall-under-churn);
``mutation_append`` / ``mutation_delete`` / ``mutation_compact`` report
rows-per-second through `IndexStore.append`, `IndexStore.delete`, and
`Compactor.run` (qps = mutation throughput for these rows).

`main(json_path=...)` writes the rows as machine-readable JSON
(`benchmarks/run.py --only search` -> BENCH_search.json) so the search
perf trajectory is recorded per CI run like encode/kernels.
"""
from __future__ import annotations

import json
import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_data
from repro import obs
from repro.configs.qinco2 import tiny
from repro.core import search, training
from repro.index import IndexStore, ShardedIndexView

SHARD_COUNTS = (1, 4, 8)
SEARCH_KW = dict(n_probe=8, n_short_aq=64, n_short_pw=16, topk=10)

# registry series attached per row (delta over the timed reps) — the
# stall-vs-compute evidence for the prefetch-pipeline rows, read from
# the public telemetry instead of pool internals. Informational only:
# scripts/check_bench.py gates qps and ignores unknown row fields.
_ROW_SERIES = ("staging_stall_seconds_total", "staging_staged_total",
               "staging_prefetch_hits_total", "staging_device_hits_total",
               "staging_host_hits_total", "search_shards_folded_total")


def _time_batches(fn, q, *, reps, warmup=2):
    """Per-batch wall-clock latencies (ms) after warmup, plus the
    metrics-registry delta over the timed reps."""
    for _ in range(warmup):
        jax.block_until_ready(fn(q))
    before = obs.snapshot()
    lat = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(q))
        lat.append((time.perf_counter() - t0) * 1e3)
    delta = obs.snapshot_delta(before, obs.snapshot())
    return np.asarray(lat), delta


def _row(mode, n_shards, timed, batch):
    # qps from the BEST batch (additive-noise-robust, like
    # `common.timeit_us`): it is the gated metric in check_bench, so a
    # single scheduler stall must not read as a regression. The latency
    # percentiles keep the full distribution for the record.
    lat_ms, delta = timed
    return {
        "mode": mode, "n_shards": n_shards,
        "qps": float(batch / (lat_ms.min() / 1e3)),
        "p50_ms": float(np.percentile(lat_ms, 50)),
        "p99_ms": float(np.percentile(lat_ms, 99)),
        "metrics": {name: obs.series_value(delta, name)
                    for name in _ROW_SERIES},
    }


def _net_rows(idx, batch, reps):
    """Closed- vs open-loop serving over the socket front door (same
    resident index, localhost TCP). Informational rows: scripts/
    check_bench.py gates known (mode, n_shards) keys only."""
    from repro.launch.search_client import (SearchClient, run_closed_loop,
                                            run_open_loop)
    from repro.launch.serve_search import SearchFrontDoor, SearchServer
    server = SearchServer(idx, micro_batch=batch, **SEARCH_KW)
    fd = SearchFrontDoor(max_queue=8 * batch, max_wait_s=1e-3)
    fd.register("default", server)
    fd.start()
    try:
        client = SearchClient("127.0.0.1", fd.port, max_retries=6,
                              backoff_base_s=5e-3)
        q = np.asarray(idx.ivf.centroids)[:batch].astype(np.float32)
        qs = np.concatenate([q] * reps)
        client.search(q)                              # connection warmup
        closed = run_closed_loop(client, qs, batch=batch)
        # offer ~2x what the closed loop achieved: enough pressure to
        # form a real queue (and shed if the server falls behind),
        # bounded wall-clock for the bench
        rate = max(50.0, 2.0 * closed.achieved_qps / batch)
        opened = run_open_loop(client, qs, rate, batch=batch, seed=0)
        rows = []
        for mode, st in (("net_closed", closed), ("net_open", opened)):
            rows.append({
                "mode": mode, "n_shards": 1,
                "qps": st.achieved_qps,
                "p50_ms": st.p50_ms, "p99_ms": st.p99_ms,
                "metrics": {"offered_qps": st.offered_qps,
                            "requests": float(st.n_requests),
                            "shed": float(st.n_shed),
                            "retries": float(st.n_retries),
                            "failed": float(st.n_failed)},
            })
        return rows
    finally:
        fd.shutdown()


def _mutation_rows(idx, xb, q, cfg, batch, reps):
    """Live-mutation rows (informational): mutation throughput for
    append/delete/compact, and search under churn — QPS over a view
    carrying delta shards + tombstones, with recall@10 against exact
    float search over the surviving vectors in ``metrics`` (the
    recall-under-churn number; deletes mask inside the scan, so churn
    must cost scan overhead, not recall)."""
    from repro.index import Compactor
    d = tempfile.mkdtemp(prefix="bench_mut_")
    try:
        n_db = len(xb)
        IndexStore.save(d, idx, shard_size=-(-n_db // 4))
        store = IndexStore(d)
        view = ShardedIndexView(d, max_resident_shards=8)
        rng = np.random.default_rng(5)
        xa = (xb[rng.integers(0, n_db, size=n_db // 8)]
              + rng.normal(scale=0.05, size=(n_db // 8, xb.shape[1]))
              ).astype(np.float32)
        t0 = time.perf_counter()
        store.append(xa)
        append_s = time.perf_counter() - t0
        dels = rng.choice(np.arange(1, n_db), size=n_db // 16,
                          replace=False)
        t0 = time.perf_counter()
        store.delete(dels)
        delete_s = time.perf_counter() - t0
        view.refresh()

        churn = _row("out_of_core_churn", 4, _time_batches(
            lambda qq: search.search_sharded(view, qq, cfg=cfg,
                                             **SEARCH_KW),
            q, reps=reps), batch)
        # recall@10 vs exact float search over the survivors
        allx = np.concatenate([xb, np.asarray(xa)])
        alive = ~store.tombstone_bits()
        ids, _ = search.search_sharded(view, q, cfg=cfg, **SEARCH_KW)
        ids = np.asarray(ids)
        d2 = ((np.asarray(q)[:, None, :] - allx[None, :, :]) ** 2).sum(-1)
        d2[:, ~alive] = np.inf
        exact = np.argsort(d2, axis=1)[:, :SEARCH_KW["topk"]]
        recall = float(np.mean([
            len(set(ids[i].tolist()) & set(exact[i].tolist()))
            / SEARCH_KW["topk"] for i in range(len(ids))]))
        churn["metrics"].update(
            recall_at_10=recall, appended_rows=float(len(xa)),
            deleted_rows=float(len(dels)))

        t0 = time.perf_counter()
        rep = Compactor(store).run()
        compact_s = time.perf_counter() - t0
        stub = {"p50_ms": 0.0, "p99_ms": 0.0}
        return [
            churn,
            dict(stub, mode="mutation_append", n_shards=4,
                 qps=float(len(xa) / append_s),
                 metrics={"rows": float(len(xa))}),
            dict(stub, mode="mutation_delete", n_shards=4,
                 qps=float(len(dels) / delete_s),
                 metrics={"rows": float(len(dels))}),
            dict(stub, mode="mutation_compact", n_shards=4,
                 qps=float(rep["n_alive"] / compact_s),
                 metrics={"rows_dropped": float(rep["rows_dropped"]),
                          "shards_written": float(rep["shards_written"])}),
        ]
    finally:
        shutil.rmtree(d, ignore_errors=True)


def run(dim=16, M=4, K=16, n_db=2048, batch=32, seed=0, *,
        shard_counts=SHARD_COUNTS, reps=10):
    xt, xb, xq, _ = bench_data("bigann", dim=dim, n_db=n_db, n_query=batch,
                               seed=seed)
    cfg = tiny(d=dim, M=M, K=K, epochs=1, batch_size=256)
    params = training.init_qinco2(jax.random.key(seed), xt, cfg)
    idx = search.build_index(jax.random.key(seed + 1), jnp.asarray(xb),
                             params, cfg, k_ivf=16, m_tilde=2,
                             n_pair_books=2 * M)
    q = jnp.asarray(xq[:batch])

    rows = [_row("resident", 1, _time_batches(
        lambda qq: search.search(idx, qq, cfg=cfg, **SEARCH_KW),
        q, reps=reps), batch)]
    rows.extend(_net_rows(idx, batch, reps))
    rows.extend(_mutation_rows(idx, xb, q, cfg, batch, reps))
    for n_shards in shard_counts:
        d = tempfile.mkdtemp(prefix="bench_search_")
        try:
            IndexStore.save(d, idx, shard_size=-(-n_db // n_shards))
            view = ShardedIndexView(d, max_resident_shards=n_shards)
            rows.append(_row("out_of_core", n_shards, _time_batches(
                lambda qq: search.search_sharded(view, qq, cfg=cfg,
                                                 **SEARCH_KW),
                q, reps=reps), batch))
            if n_shards == max(shard_counts) and n_shards > 1:
                # cold-scan rows: budget holds half the shards, so every
                # scan re-stages — with vs without the prefetch pipeline.
                # The hidden-vs-paid stall lands in each row's
                # `metrics["staging_stall_seconds_total"]` delta.
                for mode, pf in (("out_of_core_cold", True),
                                 ("out_of_core_cold_nopf", False)):
                    cold = ShardedIndexView(
                        d, max_resident_shards=max(1, n_shards // 2),
                        prefetch=pf)
                    rows.append(_row(mode, n_shards, _time_batches(
                        lambda qq: search.search_sharded(
                            cold, qq, cfg=cfg, prefetch=pf, **SEARCH_KW),
                        q, reps=reps), batch))
                # integrity-verification overhead (informational): the
                # host cache is OFF so every re-stage reassembles from
                # the mmaps and — with verify on — pays the crc32 check
                # per fill. verify=True is the serving default; the gap
                # to verify=False is the integrity tax on the worst-case
                # (cache-defeating) cold-scan path.
                for mode, vf in (("out_of_core_cold_verify", True),
                                 ("out_of_core_cold_noverify", False)):
                    cold = ShardedIndexView(
                        d, max_resident_shards=max(1, n_shards // 2),
                        host_cache_bytes=0, verify=vf)
                    rows.append(_row(mode, n_shards, _time_batches(
                        lambda qq, v=cold: search.search_sharded(
                            v, qq, cfg=cfg, **SEARCH_KW),
                        q, reps=reps), batch))
        finally:
            shutil.rmtree(d, ignore_errors=True)
    return rows


def main(fast=True, json_path=None):
    rows = run(n_db=2048 if fast else 16384, reps=10 if fast else 30,
               shard_counts=SHARD_COUNTS if fast else SHARD_COUNTS + (16,))
    print("mode,n_shards,qps,p50_ms,p99_ms")
    for r in rows:
        print(f"{r['mode']},{r['n_shards']},{r['qps']:.0f},"
              f"{r['p50_ms']:.2f},{r['p99_ms']:.2f}")
    if json_path is not None:
        with open(json_path, "w") as f:
            json.dump({"device": jax.default_backend(), "rows": rows}, f,
                      indent=2)
        print(f"[search_throughput] wrote {json_path}")
    return rows


if __name__ == "__main__":
    main(fast=False, json_path="BENCH_search.json")
