"""Table 3: compression MSE + R@1 ladder — baselines (OPQ/RQ/LSQ) and the
QINCo -> QINCo2 ablation path (improved training/arch, pre-selection,
beam search, larger eval beam). Synthetic stand-in data (DESIGN.md §7):
the paper's ORDERING claims are the reproduction target, not absolute MSE.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_data, emit, mse, recall_at
from repro.configs.qinco2 import QincoConfig, tiny
from repro.core import encode as enc
from repro.core import lsq, rq, training


def _recall(xq, gt, recon_db):
    d2 = ((np.asarray(xq)[:, None] - np.asarray(recon_db)[None]) ** 2).sum(-1)
    return float((np.argmin(d2, 1) == np.asarray(gt)).mean())


def run(dataset="bigann", M=4, K=16, epochs=4, dim=24, seed=0, verbose=False):
    xt, xb, xq, gt = bench_data(dataset, dim=dim, seed=seed)
    rows = []
    key = jax.random.key(seed)

    def row(name, recon, train_s=None):
        rows.append({"method": name, "mse": mse(xb, recon),
                     "r@1": _recall(xq, gt, recon),
                     "train_s": train_s})

    # ---- classic baselines --------------------------------------------------
    t0 = time.time()
    cbs = rq.pq_train(key, jnp.asarray(xt), M, K)
    row("OPQ/PQ", rq.pq_decode(cbs, rq.pq_encode(cbs, jnp.asarray(xb))),
        time.time() - t0)
    t0 = time.time()
    opq = rq.opq_train(key, jnp.asarray(xt), M, K, outer=3)
    row("OPQ", rq.opq_decode(opq, rq.opq_encode(opq, jnp.asarray(xb))),
        time.time() - t0)
    t0 = time.time()
    rcbs = rq.rq_train(key, jnp.asarray(xt), M, K)
    _, xh = rq.rq_encode(rcbs, jnp.asarray(xb), B=1)
    row("RQ", xh, time.time() - t0)
    t0 = time.time()
    lcbs = lsq.lsq_train(key, jnp.asarray(xt), M, K)
    lcodes = lsq.lsq_encode(lcbs, jnp.asarray(xb))
    row("LSQ", lsq.lsq_decode(lcbs, lcodes), time.time() - t0)

    # ---- QINCo ladder -------------------------------------------------------
    def train_variant(name, cfg, A_eval=None, B_eval=None):
        t0 = time.time()
        params, _ = training.train(jax.random.key(seed + 1), xt, cfg,
                                   verbose=False)
        ts = time.time() - t0
        codes, xhat, _ = enc.encode(params, jnp.asarray(xb), cfg,
                                    A_eval or cfg.A_eval,
                                    B_eval or cfg.B_eval)
        row(name, xhat, ts)
        return params

    base = dict(d=dim, M=M, K=K, epochs=epochs, batch_size=512)
    # QINCo (reproduction): d_e = d, greedy exhaustive
    train_variant("QINCo (reproduction)",
                  tiny(**base, de=dim, dh=32, L=1, A_train=K, B_train=1,
                       A_eval=K, B_eval=1, qinco1_mode=True,
                       name="qinco1-repro"))
    # + improved architecture (d_e decouple + residuals)
    train_variant("+ improved arch/training",
                  tiny(**base, de=32, dh=48, L=2, A_train=K, B_train=1,
                       A_eval=K, B_eval=1, name="qinco2-arch"))
    # + candidate pre-selection
    train_variant("+ pre-selection (A=8,B=1)",
                  tiny(**base, de=32, dh=48, L=2, A_train=8, B_train=1,
                       A_eval=8, B_eval=1, name="qinco2-pre"))
    # + beam search
    params = train_variant("+ beam (A=4,B=8)",
                           tiny(**base, de=32, dh=48, L=2, A_train=4,
                                B_train=8, A_eval=4, B_eval=8,
                                name="qinco2-beam"))
    # + larger eval beam (no retrain)
    cfg = tiny(**base, de=32, dh=48, L=2, A_train=4, B_train=8,
               A_eval=8, B_eval=16, name="qinco2-beam")
    codes, xhat, _ = enc.encode(params, jnp.asarray(xb), cfg, 8, 16)
    rows.append({"method": "+ larger eval beam (QINCo2)",
                 "mse": mse(xb, xhat), "r@1": _recall(xq, gt, xhat),
                 "train_s": None})
    return rows


def main(fast=True):
    rows = run(epochs=2 if fast else 6)
    print("method,mse,r@1,train_s")
    for r in rows:
        ts = f"{r['train_s']:.1f}" if r["train_s"] else "-"
        print(f"{r['method']},{r['mse']:.5f},{r['r@1']:.4f},{ts}")
    return rows


if __name__ == "__main__":
    main(fast=False)
