"""Roofline report: reads experiments/dryrun/*.json, prints the per-cell
three-term table (compute / memory / collective seconds per device), the
dominant bottleneck, MODEL_FLOPS ratio, and HBM fit — the §Roofline source.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.launch.mesh import HW


def load(dry_dir: Path):
    recs = []
    for p in sorted(dry_dir.glob("*.json")):
        r = json.loads(p.read_text())
        r["_file"] = p.name
        recs.append(r)
    return recs


def fmt_row(r):
    if not r.get("runnable", True):
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"skip | — | {r.get('skip_reason', '')[:40]} |")
    if r.get("error"):
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"ERROR | — | {r['error'][:40]} |")
    am = r["analytic"]
    fit = am.get("note_hbm_fit_bytes", 0) <= HW["hbm_bytes"]
    frac = r.get("roofline_fraction", 0.0)
    mf = r.get("model_hlo_ratio", 0.0)
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['t_compute_s']:.4f} | {r['t_memory_s']:.4f} | "
            f"{r['t_collective_s']:.4f} | {r['bottleneck']} | "
            f"{frac:.2f} | fit={'Y' if fit else 'N'} "
            f"mf_ratio={mf:.2f} |")


def report(dry_dir, *, single_pod_only=False, as_markdown=True):
    recs = load(Path(dry_dir))
    if single_pod_only:
        recs = [r for r in recs if r.get("mesh") == "16x16"]
    lines = [
        "| arch | shape | mesh | t_compute | t_memory | t_collective | "
        "bottleneck | roofline_frac | notes |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        lines.append(fmt_row(r))
    ok = [r for r in recs if r.get("runnable", True) and not r.get("error")]
    doms = {}
    for r in ok:
        doms[r["bottleneck"]] = doms.get(r["bottleneck"], 0) + 1
    lines.append("")
    lines.append(f"cells: {len(ok)} ok / {len(recs)} total; "
                 f"bottlenecks: {doms}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--single-pod", action="store_true")
    args = ap.parse_args()
    print(report(args.dir, single_pod_only=args.single_pod))


if __name__ == "__main__":
    main()
