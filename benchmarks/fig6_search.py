"""Fig. 6: search speed (QPS) vs recall trade-off for IVF-RQ vs
IVF-QINCo2 (cascade), sweeping n_probe and shortlist sizes."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_data, recall_at, timeit_us
from repro.configs.qinco2 import tiny
from repro.core import ivf as ivf_mod
from repro.core import rq as rq_mod
from repro.core import search, training
from repro.core.kmeans import pairwise_sqdist


def run(dim=24, M=4, K=16, epochs=2, n_db=6000, seed=0):
    xt, xb, xq, gt = bench_data("bigann", dim=dim, n_db=n_db, n_query=64,
                                seed=seed)
    cfg = tiny(d=dim, M=M, K=K, de=32, dh=48, L=2, A_train=4, B_train=8,
               A_eval=8, B_eval=16, epochs=epochs, batch_size=512)
    params, _ = training.train(jax.random.key(seed), xt, cfg, verbose=False)
    idx = search.build_index(jax.random.key(seed + 1), jnp.asarray(xb),
                             params, cfg, k_ivf=64, m_tilde=2,
                             n_pair_books=2 * M)
    q = jnp.asarray(xq)
    rows = []

    # ---- IVF-RQ baseline ----------------------------------------------------
    rcbs = rq_mod.rq_train(jax.random.key(0), jnp.asarray(xt), M, K)
    resid = ivf_mod.residual_to_centroid(idx.ivf, jnp.asarray(xb),
                                         idx.ivf.assignments)
    rq_codes, _ = rq_mod.rq_encode(rcbs, resid, B=4)
    rq_recon = (rq_mod.rq_decode(rcbs, rq_codes)
                + idx.ivf.centroids[idx.ivf.assignments])

    def rq_search(q, n_probe):
        _, cand, mask = ivf_mod.probe(idx.ivf, q, n_probe)
        d2 = jnp.sum((q[:, None] - rq_recon[cand]) ** 2, -1)
        d2 = jnp.where(mask, d2, jnp.inf)
        top = jnp.argmin(d2, 1)
        return jnp.take_along_axis(cand, top[:, None], 1)

    for n_probe in (1, 2, 4, 8, 16):
        fn = jax.jit(lambda qq: rq_search(qq, n_probe))
        t = timeit_us(fn, q) / len(xq)
        r1 = recall_at(np.asarray(fn(q)), gt, 1)
        rows.append({"method": "IVF-RQ", "n_probe": n_probe, "short": "-",
                     "qps": 1e6 / t, "r@1": r1})

    # ---- IVF-QINCo2 cascade --------------------------------------------------
    for n_probe, ns_aq, ns_pw in [(1, 16, 4), (2, 32, 8), (4, 32, 8),
                                  (8, 64, 16), (16, 64, 16)]:
        fn = jax.jit(lambda qq: search.search(
            idx, qq, n_probe=n_probe, n_short_aq=ns_aq, n_short_pw=ns_pw,
            topk=1, cfg=cfg)[0])
        t = timeit_us(fn, q) / len(xq)
        r1 = recall_at(np.asarray(fn(q)), gt, 1)
        rows.append({"method": "IVF-QINCo2", "n_probe": n_probe,
                     "short": f"{ns_aq}/{ns_pw}", "qps": 1e6 / t, "r@1": r1})
    return rows


def main(fast=True):
    rows = run(epochs=1 if fast else 3, n_db=4000 if fast else 8000)
    print("method,n_probe,shortlists,qps,r@1")
    for r in rows:
        print(f"{r['method']},{r['n_probe']},{r['short']},"
              f"{r['qps']:.0f},{r['r@1']:.4f}")
    return rows


if __name__ == "__main__":
    main(fast=False)
