"""Encode throughput across the (A, B) grid: fused vs unfused beam steps.

Encoding is QINCo2's dominant database-build cost (paper §3.2), and since
the fused-selection PR every beam step can run either as the single-launch
`ops.preselect_topk` / `ops.f_theta_err` path (``fused=True``, the
default — nothing (A*B)-wide or K-wide leaves VMEM) or as the historical
`ops.f_theta` + `lax.top_k` composite (``fused=False``). This section
times both on both dispatch backends over the three encode modes —
QINCo1-greedy (A=K, B=1), pre-selection (A<K, B=1), beam (B>1) — and
reports vectors/second per row.

On TPU the pallas rows are the native-kernel path and the fused-vs-unfused
delta is the HBM-traffic claim; on CPU the pallas rows run in interpret
mode (a correctness/coverage signal, not a speed claim — every row records
which mode was measured). `main(json_path=...)` writes the rows as
machine-readable JSON (`benchmarks/run.py --only encode` ->
BENCH_encode.json) so the encode perf trajectory has data points.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp

from benchmarks.common import bench_data, timeit_us
from repro.configs.qinco2 import tiny
from repro.core import encode as enc
from repro.core import training

BACKENDS = ("xla", "pallas")
# (A, B) grid: greedy (A=K), pre-selection (A<K, B=1), small + eval beams
GRID = ((16, 1), (4, 1), (4, 4), (8, 8))


def run(dim=16, M=4, K=16, n=256, seed=0, *, backends=BACKENDS, grid=GRID,
        reps=3):
    xt, xb, _, _ = bench_data("bigann", dim=dim, n_db=max(n, 512),
                              n_query=8, seed=seed)
    cfg = tiny(d=dim, M=M, K=K, epochs=1, batch_size=256)
    params = training.init_qinco2(jax.random.key(seed), xt, cfg)
    xbj = jnp.asarray(xb[:n])
    mode = "native" if jax.default_backend() == "tpu" else "interpret"

    rows = []
    for be in backends:
        for A, B in grid:
            for fused in (True, False):
                t = timeit_us(
                    lambda x: enc.encode(params, x, cfg, A, B, backend=be,
                                         fused=fused)[0], xbj, reps=reps)
                rows.append({
                    "op": f"encode(A={A},B={B})", "backend": be,
                    "fused": fused,
                    "mode": mode if be == "pallas" else "-",
                    "us_per_vec": t / n,
                    "vecs_per_s": 1e6 * n / t,
                })
    return rows


def main(fast=True, json_path=None):
    rows = run(n=256 if fast else 2048, reps=3 if fast else 7)
    print("op,backend,fused,mode,us_per_vec,vecs_per_s")
    for r in rows:
        print(f"{r['op']},{r['backend']},{int(r['fused'])},{r['mode']},"
              f"{r['us_per_vec']:.3f},{r['vecs_per_s']:.0f}")
    if json_path is not None:
        with open(json_path, "w") as f:
            json.dump({"device": jax.default_backend(), "rows": rows}, f,
                      indent=2)
        print(f"[encode_throughput] wrote {json_path}")
    return rows


if __name__ == "__main__":
    main(fast=False, json_path="BENCH_encode.json")
