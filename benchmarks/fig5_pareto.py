"""Fig. 4/5: Pareto front of MSE vs encoding time over (L, d_e/d_h, A, B),
and Fig. S3 dynamic rates (--rates): MSE after m <= M steps."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_data, mse, timeit_us
from repro.configs.qinco2 import tiny
from repro.core import encode as enc
from repro.core import qinco, training


def run_pareto(dim=24, M=4, K=16, epochs=2, seed=0):
    xt, xb, xq, gt = bench_data("bigann", dim=dim, seed=seed)
    xbj = jnp.asarray(xb)
    rows = []
    for (L, de, dh) in [(1, 24, 32), (2, 32, 48), (4, 48, 64)]:
        cfg = tiny(d=dim, M=M, K=K, de=de, dh=dh, L=L, A_train=4, B_train=8,
                   A_eval=8, B_eval=8, epochs=epochs, batch_size=512,
                   name=f"pareto-L{L}")
        params, _ = training.train(jax.random.key(seed), xt, cfg,
                                   verbose=False)
        for (A, B) in [(2, 2), (4, 4), (8, 8), (8, 16)]:
            t_us = timeit_us(
                lambda x: enc.encode(params, x, cfg, A, B)[0], xbj) / len(xb)
            _, xhat, _ = enc.encode(params, xbj, cfg, A, B)
            rows.append({"L": L, "de": de, "dh": dh, "A": A, "B": B,
                         "enc_us": t_us, "mse": mse(xb, xhat)})
    return rows


def run_rates(dim=24, K=16, epochs=2, seed=0):
    """Fig S3: a model trained at M=6 evaluated truncated to m<=6 vs models
    trained at smaller M."""
    xt, xb, xq, gt = bench_data("bigann", dim=dim, seed=seed)
    xbj = jnp.asarray(xb)
    out = {}
    for M in (2, 4, 6):
        cfg = tiny(d=dim, M=M, K=K, de=32, dh=48, L=2, A_train=4, B_train=8,
                   A_eval=8, B_eval=8, epochs=epochs, batch_size=512,
                   name=f"rates-M{M}")
        params, _ = training.train(jax.random.key(seed), xt, cfg,
                                   verbose=False)
        codes, _, _ = enc.encode(params, xbj, cfg, 8, 8)
        traj = qinco.decode_partial(params, codes, cfg)
        out[M] = [float(jnp.mean(jnp.sum((xbj[:, None] - traj) ** 2, -1)
                                 [:, m])) for m in range(M)]
    return out


def main(fast=True, rates=False):
    if rates:
        out = run_rates(epochs=1 if fast else 3)
        print("trained_M,m,mse")
        for M, arr in out.items():
            for m, v in enumerate(arr):
                print(f"{M},{m + 1},{v:.5f}")
        return out
    rows = run_pareto(epochs=1 if fast else 3)
    print("L,de,dh,A,B,enc_us_per_vec,mse")
    for r in rows:
        print(f"{r['L']},{r['de']},{r['dh']},{r['A']},{r['B']},"
              f"{r['enc_us']:.2f},{r['mse']:.5f}")
    return rows


if __name__ == "__main__":
    import sys
    main(fast=False, rates="--rates" in sys.argv)
