"""Table 4: approximate decoders for QINCo2 codes — direct R@1 and the
recall of QINCo2 re-ranking a 10-element shortlist built by each method.
Also prints the greedy pair-selection trace (Table S3) with --pairs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_data, mse
from repro.configs.qinco2 import tiny
from repro.core import aq, encode as enc, ivf as ivf_mod, pairwise as pw
from repro.core import qinco, search, training


def run(dataset="bigann", M=4, K=16, epochs=3, dim=24, seed=0,
        show_pairs=False):
    xt, xb, xq, gt = bench_data(dataset, dim=dim, seed=seed)
    cfg = tiny(d=dim, M=M, K=K, epochs=epochs, batch_size=512, de=32,
               dh=48, L=2, A_train=4, B_train=8, A_eval=8, B_eval=16)
    params, _ = training.train(jax.random.key(seed), xt, cfg, verbose=False)
    idx = search.build_index(jax.random.key(seed + 1), jnp.asarray(xb),
                             params, cfg, k_ivf=32, m_tilde=2,
                             n_pair_books=2 * M, verbose=show_pairs)
    q = jnp.asarray(xq)
    rows = []

    def eval_decoder(name, scores):
        """scores: (Q, N) higher=closer; direct R@1 + shortlist-10 rerank."""
        direct = np.asarray(jnp.argmax(scores, 1))
        r1 = float((direct == gt).mean())
        _, short = jax.lax.top_k(scores, 10)
        flat = short.reshape(-1)
        recon = (qinco.decode(params, idx.codes[flat], cfg)
                 + idx.ivf.centroids[idx.ivf.assignments[flat]])
        recon = recon.reshape(q.shape[0], 10, dim)
        d2 = jnp.sum((q[:, None] - recon) ** 2, -1)
        rr = np.asarray(jnp.take_along_axis(short, jnp.argmin(d2, 1)[:, None],
                                            1))[:, 0]
        rows.append({"decoder": name, "r@1": r1,
                     "r@1_short10": float((rr == gt).mean())})

    # QINCo2 decoder, exhaustive (the ceiling; 'no shortlist' row)
    recon = (qinco.decode(params, idx.codes, cfg)
             + idx.ivf.centroids[idx.ivf.assignments])
    d2 = ((np.asarray(q)[:, None] - np.asarray(recon)[None]) ** 2).sum(-1)
    rows.append({"decoder": "QINCo2 (no shortlist)",
                 "r@1": float((np.argmin(d2, 1) == gt).mean()),
                 "r@1_short10": None})

    # AQ (joint least-squares) — includes centroid term
    lut = aq.adc_lut(idx.aq_books, q)
    clut = jnp.einsum("qd,kd->qk", q, idx.ivf.centroids)
    ip = jnp.sum(jnp.take_along_axis(
        lut[:, None], idx.codes[None, ..., None], axis=3)[..., 0], axis=2)
    ip = ip + clut[:, idx.ivf.assignments]
    eval_decoder("AQ", 2 * ip - idx.aq_norms[None])

    # RQ-style sequential decoder
    resid = ivf_mod.residual_to_centroid(idx.ivf, jnp.asarray(xb),
                                         idx.ivf.assignments)
    rq_books = aq.fit_rq_decoder(idx.codes, resid, M, K)
    rq_recon = aq.aq_decode(rq_books, idx.codes) + idx.ivf.centroids[
        idx.ivf.assignments]
    rq_norms = jnp.sum(rq_recon ** 2, -1)
    lut2 = aq.adc_lut(rq_books, q)
    ip2 = jnp.sum(jnp.take_along_axis(
        lut2[:, None], idx.codes[None, ..., None], axis=3)[..., 0], axis=2)
    ip2 = ip2 + clut[:, idx.ivf.assignments]
    eval_decoder("RQ", 2 * ip2 - rq_norms[None])

    # consecutive pairs
    ext = idx.ext_codes
    cons = pw.consecutive_pairs_decoder(ext, jnp.asarray(xb), K)
    cons_norms = jnp.sum(cons.decode(ext) ** 2, -1)
    sc = pw.pairwise_scores(pw.pairwise_lut(cons.codebooks, q), ext,
                            cons.pairs, K, cons_norms)
    eval_decoder(f"RQ w/ M/2={len(cons.pairs)} consecutive pairs", sc)

    # optimized pairs (the paper's contribution)
    sc = pw.pairwise_scores(pw.pairwise_lut(idx.pw.codebooks, q), ext,
                            idx.pw.pairs, K, idx.pw_norms)
    eval_decoder(f"RQ w/ 2M={len(idx.pw.pairs)} optimized pairs", sc)

    if show_pairs:   # Table S3 trace
        r = jnp.asarray(xb).astype(jnp.float32)
        print("pair-selection trace (Table S3):")
        for t, (i, j) in enumerate(idx.pw.pairs):
            r = r - idx.pw.codebooks[t, ext[:, i] * K + ext[:, j]]
            tag = (f"I{i}" if i < M else f"I~{i - M}",
                   f"I{j}" if j < M else f"I~{j - M}")
            print(f"  step {t}: pair={tag} mse={mse(jnp.zeros_like(r), r):.5f}")
    return rows


def main(fast=True, show_pairs=False):
    rows = run(epochs=2 if fast else 4, show_pairs=show_pairs)
    print("decoder,r@1,r@1_short10")
    for r in rows:
        s10 = f"{r['r@1_short10']:.4f}" if r["r@1_short10"] is not None else "-"
        print(f"{r['decoder']},{r['r@1']:.4f},{s10}")
    return rows


if __name__ == "__main__":
    import sys
    main(fast=False, show_pairs="--pairs" in sys.argv)
